"""Full-model serving benchmark: dense vs paged cache backend, end to end.

    PYTHONPATH=src python -m benchmarks.model_serve [--smoke | --full]

Where benchmarks/paged_decode.py measures the paged *kernel* against
synthetic latents, this section serves an actual transformer through both
runtime.serve_loop backends and reports, per scenario:

* **tokens/s** dense vs paged — the headline serving rate (real on TPU;
  informational in CPU interpret mode, where Python dispatch dominates);
* **deterministic work proxies** — paged page DMAs (per-step schedule
  accounting x L layers), the dense backend's equivalent row reads
  (B x max_len x L per step: a contiguous cache scans every reserved row),
  their reduction factor, decode-schedule rebuilds (one per block_k
  boundary / churn event, never per layer) and prefill compile counts
  (pow2 buckets dense, fixed chunk paged).  These gate CI regressions
  exactly (see benchmarks/run.py check_regression);
* **speculative decode** — a draft-verify row (``--speculate ngram``)
  gated on exact greedy parity with off, accepted_tokens_per_step > 1.0,
  and page-DMA bytes per *accepted* token at or below the off baseline;
* **SLA scheduling** — a multi-tenant bursty-traffic row comparing
  token-budgeted prefill/decode interleaving against phased admission:
  p50/p99 TTFT and per-token latency per priority class on the
  deterministic work-unit clock, gated on exact greedy parity and an
  interactive-class p99 TTFT at or below phased at equal units/token.

``run()`` returns a JSON-able dict merged into BENCH_decode.json under
``model_serve`` and summarized into BENCH_history.json.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model_zoo import build_model
from repro.runtime.serve_loop import (
    PagedServingSession,
    ServingSession,
    ShardedPagedServingSession,
)


def _on_tpu() -> bool:
    return any(d.platform == "tpu" for d in jax.devices())


def _geometry(tier: str) -> dict:
    """Scenario matrix per tier.  Prompts are ragged on purpose: raggedness
    is where paging beats per-slot max_len reservation."""
    if tier == "full":  # serving scale (TPU)
        return dict(
            n_layers=8, max_len=4096, page=128, block_k=512, chunk=256,
            num_pages=512, steps=64,
            prompts=[384, 1536, 801, 2040, 512, 999],
            prefix=1024, suffixes=[64, 33, 17],
        )
    if tier == "smoke":  # CI interpret mode: seconds
        return dict(
            n_layers=2, max_len=128, page=16, block_k=32, chunk=16,
            num_pages=64, steps=6,
            prompts=[24, 49, 16],
            prefix=40, suffixes=[5, 9],
        )
    return dict(  # default: local CPU sanity, ~a minute
        n_layers=2, max_len=256, page=16, block_k=64, chunk=32,
        num_pages=128, steps=10,
        prompts=[24, 49, 16, 70],
        prefix=66, suffixes=[5, 9, 13],
    )


def _build(tier: str):
    cfg = get_config("deepseek-v2-mla", smoke=True)
    g = _geometry(tier)
    if g["n_layers"] != cfg.n_layers:
        cfg = dataclasses.replace(cfg, n_layers=g["n_layers"])
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, g


def _timed_steps(sess, n: int) -> float:
    """Wall time of ``n`` decode steps after one warmup step (seconds)."""
    sess.step()  # warmup: compiles / first schedule build
    t0 = time.perf_counter()
    for _ in range(n):
        sess.step()
    cache = getattr(sess, "cache", None)
    if cache is not None and hasattr(cache, "pages"):
        jax.block_until_ready(cache.pages)
    elif hasattr(sess, "shards"):  # sharded: one pool per data shard
        jax.block_until_ready([s.cache.pages for s in sess.shards])
    return time.perf_counter() - t0


def _serve_scenario(cfg, model, params, g, *, shared_prefix: bool) -> dict:
    rng = np.random.default_rng(0)
    paged = PagedServingSession(
        model, params, num_pages=g["num_pages"], page_size=g["page"],
        block_k=g["block_k"], prefill_chunk=g["chunk"],
        prefix_sharing=shared_prefix,
    )
    # Size the dense batch to exactly the admitted requests: every slot
    # decodes every step whether occupied or not, so spare slots would
    # understate dense tokens/s and flatter the paged ratio.
    dense_slots = (
        1 + len(g["suffixes"]) if shared_prefix else len(g["prompts"])
    )
    dense = ServingSession(model, params, batch_size=dense_slots,
                           max_len=g["max_len"])

    if shared_prefix:
        prefix = rng.integers(2, cfg.vocab_size, size=g["prefix"]).tolist()
        parent = paged.add_request(prefix)
        dense.add_request(prefix)
        for n in g["suffixes"]:
            suffix = rng.integers(2, cfg.vocab_size, size=n).tolist()
            paged.admit_with_prefix(parent, suffix, prefix_len=len(prefix))
            dense.add_request(prefix + suffix)
        n_live = 1 + len(g["suffixes"])
    else:
        for n in g["prompts"]:
            prompt = rng.integers(2, cfg.vocab_size, size=n).tolist()
            paged.add_request(prompt)
            dense.add_request(prompt)
        n_live = len(g["prompts"])

    steps = g["steps"]
    dt_dense = _timed_steps(dense, steps)
    dt_paged = _timed_steps(paged, steps)
    toks = n_live * steps

    # Deterministic proxies: the dense backend reads every reserved row of
    # every active slot in every layer each step; the paged backend fetches
    # exactly the live pages its one-per-step schedule names.
    n_layers = cfg.n_layers
    dense_row_reads = (steps + 1) * n_live * g["max_len"] * n_layers
    work = paged.work_stats()
    fetched_rows = work["page_dmas"] * g["page"]
    res = {
        "requests": n_live,
        "decode_steps": work["decode_steps"],
        "tokens_per_s_dense": toks / max(dt_dense, 1e-9),
        "tokens_per_s_paged": toks / max(dt_paged, 1e-9),
        "page_dmas_paged": work["page_dmas"],
        "page_dma_bytes_paged": work["page_dma_bytes"],
        "rows_attended_paged": work["rows_attended"],
        "dense_row_reads": dense_row_reads,
        "read_reduction_vs_dense": dense_row_reads / max(fetched_rows, 1),
        "schedule_rebuilds": paged.scheduler_stats["rebuilds"],
        "schedule_hits": paged.scheduler_stats["hits"],
        "prefill_compiles_paged": paged.prefill_compiles,
        "prefill_compiles_dense": dense.prefill_compiles,
        "aliased_pages": work["aliased_pages"],
    }
    paged.close()  # leak audit: raises unless every page returns to free
    return res


def _sharded_scenario(cfg, model, params, g, *, shards: int = 2) -> dict:
    """Sharded-vs-single-host row: the same ragged request stream served
    through one paged session and a :class:`ShardedPagedServingSession`
    with the pool + work queue split over ``shards`` data shards.

    Logical shards (mesh=None) keep this runnable on single-device CI; the
    CPU-mesh job drives the same class over real devices.  Gates: greedy
    outputs must match the single-host backend exactly
    (``greedy_match_vs_single == 1.0`` — routing is data-parallel, so each
    request's kernel math is shard-local and bit-identical), and the
    per-shard page-DMA work split must stay balanced
    (``shard_imbalance = max/mean <= 2.0`` on this ragged stream).
    """
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=n).tolist() for n in g["prompts"]
    ]
    single = PagedServingSession(
        model, params, num_pages=g["num_pages"], page_size=g["page"],
        block_k=g["block_k"], prefill_chunk=g["chunk"],
    )
    sharded = ShardedPagedServingSession(
        model, params, num_pages=g["num_pages"], shards=shards,
        page_size=g["page"], block_k=g["block_k"], prefill_chunk=g["chunk"],
    )
    r_single = [single.add_request(p) for p in prompts]
    r_sharded = [sharded.add_request(p) for p in prompts]
    dt_single = _timed_steps(single, g["steps"])
    dt_sharded = _timed_steps(sharded, g["steps"])
    outs_single = [single.outputs[r] for r in r_single]
    outs_sharded = [sharded.outputs[r] for r in r_sharded]
    matches = sum(a == b for a, b in zip(outs_single, outs_sharded))
    toks = len(prompts) * g["steps"]
    work = sharded.work_stats()
    bal = work["balance"]
    res = {
        "requests": len(prompts),
        "num_shards": shards,
        "decode_steps": work["decode_steps"],
        "tokens_per_s_paged": toks / max(dt_sharded, 1e-9),
        "tokens_per_s_single_host": toks / max(dt_single, 1e-9),
        "page_dmas_paged": work["page_dmas"],
        "page_dma_bytes_paged": work["page_dma_bytes"],
        "schedule_rebuilds": sharded.scheduler_stats["rebuilds"],
        "greedy_match_vs_single": matches / len(prompts),
        "shard_imbalance": bal["imbalance"],
    }
    for i, st in enumerate(work["per_shard"]):
        res[f"shard{i}_page_dmas"] = st["page_dmas"]
        res[f"shard{i}_rows_attended"] = st["rows_attended"]
    single.close()  # leak audits: raise unless every pool drains to free
    sharded.close()
    return res


def _dtype_scenario(cfg, model, params, g) -> dict:
    """Int8-vs-bf16 cache-dtype row: the same ragged request stream served
    through two paged sessions that differ only in kv_dtype.

    Identical schedules fetch identical page counts; what the dtype changes
    is **bytes per page** (the bandwidth decode is bound by) — ISSUE-5
    gates ``dma_bytes_reduction_vs_bf16 >= 1.9`` — plus greedy parity,
    reported as the fraction of requests whose tokens match exactly.
    """
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=n).tolist() for n in g["prompts"]
    ]
    sessions, outs, dts = {}, {}, {}
    for name in ("bf16", "int8"):
        sess = PagedServingSession(
            model, params, num_pages=g["num_pages"], page_size=g["page"],
            block_k=g["block_k"], prefill_chunk=g["chunk"], kv_dtype=name,
        )
        rids = [sess.add_request(p) for p in prompts]
        dts[name] = _timed_steps(sess, g["steps"])
        sessions[name] = sess
        outs[name] = [sess.outputs[r] for r in rids]
    toks = len(prompts) * g["steps"]
    work = {k: s.work_stats() for k, s in sessions.items()}
    matches = sum(a == b for a, b in zip(outs["bf16"], outs["int8"]))
    for s in sessions.values():
        s.close()  # leak audit
    return {
        "requests": len(prompts),
        "decode_steps": work["int8"]["decode_steps"],
        "tokens_per_s_paged": toks / max(dts["int8"], 1e-9),
        "tokens_per_s_paged_bf16": toks / max(dts["bf16"], 1e-9),
        "page_dmas_paged": work["int8"]["page_dmas"],
        "page_dma_bytes_paged": work["int8"]["page_dma_bytes"],
        "page_dma_bytes_bf16": work["bf16"]["page_dma_bytes"],
        "dma_bytes_reduction_vs_bf16": (
            work["bf16"]["page_dma_bytes"]
            / max(work["int8"]["page_dma_bytes"], 1)
        ),
        "greedy_match_vs_bf16": matches / len(prompts),
        "schedule_rebuilds": sessions["int8"].scheduler_stats["rebuilds"],
    }


def _speculative_scenario(cfg, model, params, g, *, draft_k: int = 4) -> dict:
    """Speculative-vs-off row on a repetitive-suffix stream.

    Two paged sessions serve the same prompts (random head + cycled 3-token
    tail — the traffic shape n-gram drafting exists for); ``off`` emits one
    token per request-step, ``ngram`` verifies ``draft_k`` rows per fused
    step and rolls rejected drafts back.  Gates: the speculative token
    stream must be an exact prefix-match of the non-speculative one
    (``greedy_match_vs_off == 1.0`` — drafting can never change tokens),
    ``accepted_tokens_per_step > 1.0`` (the drafter actually lands
    something), and ``page_dma_bytes_per_accepted_token`` at or below the
    off baseline — the amortization headline: the same page fetches feeding
    k verified rows instead of one.
    """
    rng = np.random.default_rng(0)
    pattern = rng.integers(2, cfg.vocab_size, size=3).tolist()
    prompts = []
    for n in g["prompts"][:2]:
        head = rng.integers(2, cfg.vocab_size, size=max(n // 2, 1)).tolist()
        tail = [pattern[i % 3] for i in range(n - len(head))]
        prompts.append(head + tail)
    # Long enough for the greedy stream to settle into the loops the
    # drafter feeds on (acceptance is back-loaded: early steps are the
    # stream finding its cycle, so short horizons understate it).
    target = 5 * g["steps"]

    def _mk(speculate):
        return PagedServingSession(
            model, params, num_pages=g["num_pages"], page_size=g["page"],
            block_k=g["block_k"], prefill_chunk=g["chunk"],
            speculate=speculate, draft_k=draft_k,
        )

    off = _mk("off")
    r_off = [off.add_request(p) for p in prompts]
    t0 = time.perf_counter()
    for _ in range(target):
        off.step()
    jax.block_until_ready(off.cache.pages)
    dt_off = time.perf_counter() - t0

    spec = _mk("ngram")
    r_spec = [spec.add_request(p) for p in prompts]
    t0 = time.perf_counter()
    it = 0
    while it < draft_k * target and any(
        len(spec.outputs[rs]) < len(off.outputs[ro])
        for ro, rs in zip(r_off, r_spec)
    ):
        spec.step()
        it += 1
    jax.block_until_ready(spec.cache.pages)
    dt_spec = time.perf_counter() - t0

    # A speculative step can overshoot the horizon by up to draft_k - 1
    # tokens; parity is prefix-exact against the off stream.
    matches = sum(
        off.outputs[ro] == spec.outputs[rs][: len(off.outputs[ro])]
        for ro, rs in zip(r_off, r_spec)
    )
    work, work_off = spec.work_stats(), off.work_stats()
    toks_off = len(prompts) * target
    toks_spec = sum(len(spec.outputs[r]) - 1 for r in r_spec)
    off.close()  # leak audits
    spec.close()
    return {
        "requests": len(prompts),
        "draft_k": draft_k,
        "decode_steps": work["decode_steps"],
        "request_steps": work["request_steps"],
        "query_rows": work["query_rows"],
        "accepted_tokens": work["accepted_tokens"],
        "accepted_tokens_per_step": work["accepted_tokens_per_step"],
        "tokens_per_s_paged": toks_spec / max(dt_spec, 1e-9),
        "tokens_per_s_off": toks_off / max(dt_off, 1e-9),
        "page_dmas_paged": work["page_dmas"],
        "page_dma_bytes_paged": work["page_dma_bytes"],
        "page_dma_bytes_per_accepted_token": work[
            "page_dma_bytes_per_accepted_token"
        ],
        "page_dma_bytes_per_accepted_token_off": work_off[
            "page_dma_bytes_per_accepted_token"
        ],
        "dma_per_token_vs_off": (
            work_off["page_dma_bytes_per_accepted_token"]
            / max(work["page_dma_bytes_per_accepted_token"], 1e-9)
        ),
        "greedy_match_vs_off": matches / len(prompts),
        "schedule_rebuilds": spec.scheduler_stats["rebuilds"],
    }


def _failure_recovery_scenario(cfg, model, params, g, *, shards: int = 2) -> dict:
    """Chaos row: seeded shard loss mid-stream vs the fault-free run.

    The same ragged stream runs twice through two-logical-shard sessions
    under :class:`~repro.runtime.serve_loop.ServeSupervisor` — once
    fault-free, once with a :class:`~repro.runtime.fault_injection
    .FaultPlan` that kills shard 1 halfway through.  Every victim must be
    suspended, re-routed to the survivor, replayed, and complete with the
    exact tokens of the fault-free run: gates ``completed_fraction ==
    1.0`` and ``greedy_match_vs_nofault == 1.0`` (plus a zero-leak
    host-mirror refcount sweep and zero replay mismatches).  The cost of
    recovery is the reported ``replay_token_overhead`` — replayed prefill
    tokens per generated token.
    """
    from repro.runtime.fault_injection import FaultEvent, FaultPlan
    from repro.runtime.serve_loop import ServeSupervisor

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=n).tolist() for n in g["prompts"]
    ]
    gen_len = g["steps"]
    plan = FaultPlan(
        [FaultEvent(step=max(2, g["steps"] // 2), kind="shard_loss", shard=1)]
    )

    def _run(active_plan):
        sess = ShardedPagedServingSession(
            model, params, num_pages=g["num_pages"], shards=shards,
            page_size=g["page"], block_k=g["block_k"],
            prefill_chunk=g["chunk"],
        )
        sup = ServeSupervisor(sess, gen_len=gen_len, plan=active_plan)
        for p in prompts:
            sup.submit(p)
        t0 = time.perf_counter()
        results = sup.run()
        jax.block_until_ready([s.cache.pages for s in sess.shards])
        dt = time.perf_counter() - t0
        return sess, sup, results, dt

    _, _, base, dt_base = _run(None)
    sess, sup, faulted, dt_fault = _run(plan)
    stats = sup.stats()
    completed = sum(
        i not in sup.abandoned_idx and len(faulted.get(i, [])) >= gen_len
        for i in range(len(prompts))
    )
    matches = sum(base[i] == faulted[i] for i in base if i in faulted)
    leaked = 0
    for s in sess.shards:
        sweep = s.cache.refcount_sweep()  # raises on refcount divergence
        leaked += sweep["live_pages"]
    work = sess.work_stats()
    sess.close()  # full teardown audit on the faulted session
    toks = stats["tokens_out"]
    return {
        "requests": len(prompts),
        "num_shards": shards,
        "decode_steps": work["decode_steps"],
        "supervised_steps": stats["steps"],
        "tokens_per_s_paged": toks / max(dt_fault, 1e-9),
        "tokens_per_s_nofault": toks / max(dt_base, 1e-9),
        "page_dmas_paged": work["page_dmas"],
        "page_dma_bytes_paged": work["page_dma_bytes"],
        "schedule_rebuilds": sess.scheduler_stats["rebuilds"],
        "completed_fraction": completed / len(prompts),
        "greedy_match_vs_nofault": matches / len(prompts),
        "suspends": stats["suspends"],
        "resumes": stats["resumes"],
        "replay_mismatches": stats["replay_mismatches"],
        "replay_prefill_tokens": stats["replay_prefill_tokens"],
        "replay_token_overhead": stats["replay_prefill_tokens"] / max(toks, 1),
        "leaked_pages": leaked,
    }


def _multi_tenant_scenario(cfg, model, params, g) -> dict:
    """Prefix-trie row: a multi-tenant template stream, trie-on vs trie-off.

    Every request shares a 3-block system-prompt template with a private
    ragged tail — the sustained multi-tenant traffic shape the radix trie
    exists for.  Admissions arrive in staggered waves with overlapping
    lifetimes, so hits alias both *live* requests' pages and *retained*
    (finished) prefixes.  The off twin serves the identical stream through
    the pre-trie default path (no sharing, full prefill per request).

    Gates (both cache dtypes): ``greedy_match_vs_off == 1.0`` — automatic
    admission must be invisible in the tokens, which is why only
    chunk-aligned prefill-written blocks are ever retained — and
    ``dma_bytes_reduction_vs_off >= 2.0`` at equal output tokens, the
    zero-copy adoption + nested group-prefix scheduling headline.  After
    the stream, ``reclaim_retained`` drains every retained subtree
    (eviction churn) and ``close()`` runs the refcount sweep — any leaked
    page raises, reported as ``sweep_clean``.
    """
    rng = np.random.default_rng(0)
    template = rng.integers(2, cfg.vocab_size, size=3 * g["block_k"]).tolist()
    n_req = 2 * max(len(g["prompts"]), 4)
    prompts = [
        template + rng.integers(2, cfg.vocab_size, size=5 + 3 * i).tolist()
        for i in range(n_req)
    ]
    wave_steps = max(g["steps"] // 2, 2)

    def _serve(prefix_cache, kv_dtype=None):
        sess = PagedServingSession(
            model, params, num_pages=g["num_pages"], page_size=g["page"],
            block_k=g["block_k"], prefill_chunk=g["chunk"],
            prefix_cache=prefix_cache, kv_dtype=kv_dtype,
        )
        outs, live = {}, []
        t0 = time.perf_counter()
        for w in range(n_req // 2):
            for j in range(2):
                rid = sess.add_request(prompts[2 * w + j])
                assert rid is not None, "pool sized to admit every wave"
                live.append(rid)
            for _ in range(wave_steps):
                sess.step()
            if len(live) >= 4:  # overlapping lifetimes: finish the oldest
                for r in live[:2]:
                    outs[r] = sess.finish(r)
                live = live[2:]
        for _ in range(wave_steps):
            sess.step()
        for r in live:
            outs[r] = sess.finish(r)
        jax.block_until_ready(sess.cache.pages)
        dt = time.perf_counter() - t0
        # Eviction churn before the sweep: drain every retained subtree,
        # then tear down — close() raises if any page fails to come home.
        sess.reclaim_retained(g["num_pages"])
        work = sess.work_stats()
        work["schedule_rebuilds"] = sess.scheduler_stats["rebuilds"]
        sweep = sess.close()
        clean = sweep["free_pages"] == g["num_pages"]
        return outs, work, dt, clean

    res = {"requests": n_req, "template_tokens": 3 * g["block_k"]}
    toks = {}
    for dname, dtype in (("bf16", None), ("int8", "int8")):
        off, w_off, dt_off, clean_off = _serve("off", dtype)
        on, w_on, dt_on, clean_on = _serve("trie", dtype)
        toks[dname] = sum(len(v) for v in on.values())
        assert sum(len(v) for v in off.values()) == toks[dname]
        matches = sum(on[r] == off[r] for r in off)
        suffix = "" if dname == "bf16" else "_int8"
        res[f"greedy_match_vs_off{suffix}"] = matches / n_req
        res[f"dma_bytes_reduction_vs_off{suffix}"] = (
            w_off["page_dma_bytes"] / max(w_on["page_dma_bytes"], 1)
        )
        res[f"sweep_clean{suffix}"] = float(clean_off and clean_on)
        if dname == "bf16":
            res.update({
                "decode_steps": w_on["decode_steps"],
                "tokens_per_s_paged": toks[dname] / max(dt_on, 1e-9),
                "tokens_per_s_off": toks[dname] / max(dt_off, 1e-9),
                "page_dmas_paged": w_on["page_dmas"],
                "page_dma_bytes_paged": w_on["page_dma_bytes"],
                "page_dma_bytes_off": w_off["page_dma_bytes"],
                "prefix_hit_rate": w_on["trie_hit_rate"],
                "prefix_tokens_reused": w_on["prefix_tokens_reused"],
                "prefix_tokens_reused_per_admission": w_on[
                    "prefix_tokens_reused_per_admission"
                ],
                "trie_evicted_pages": w_on["trie_evicted_pages"],
                "schedule_rebuilds": w_on["schedule_rebuilds"],
            })
    return res


def _sla_traffic(cfg, g, *, seed: int = 7, n_bursts: int = 2) -> list:
    """Seeded bursty multi-tenant traffic: (arrival, prompt, priority) per
    request, arrivals on the deterministic work-unit clock.

    Each burst opens with one *batch* long prompt (16-20 prefill chunks —
    the 32k-prompt regime scaled to the tier's chunk), then five
    *interactive* shorts and one *standard* mid-length request land inside
    the window the long's synchronous prefill would occupy.  That overlap
    is the whole scenario: under phased admission every one of them waits
    behind the long's full prefill; under token-budgeted interleaving they
    chunk in beside it.
    """
    rng = np.random.default_rng(seed)
    chunk = g["chunk"]
    subs, u = [], 0
    for _ in range(n_bursts):
        n = int(rng.integers(16 * chunk, 20 * chunk + 1))
        subs.append(
            (u, rng.integers(2, cfg.vocab_size, size=n).tolist(), 0)
        )
        for _ in range(5):
            arr = u + int(rng.integers(0, 18))
            n = int(rng.integers(max(chunk // 4, 2), 3 * chunk // 2))
            subs.append(
                (arr, rng.integers(2, cfg.vocab_size, size=n).tolist(), 2)
            )
        arr = u + int(rng.integers(0, 18))
        n = int(rng.integers(3 * chunk // 2 + 1, 7 * chunk // 2))
        subs.append(
            (arr, rng.integers(2, cfg.vocab_size, size=n).tolist(), 1)
        )
        u += int(rng.integers(30, 40))
    return subs


def _multi_tenant_sla_scenario(cfg, model, params, g, *, gen_len: int = 6) -> dict:
    """SLA row: token-budgeted prefill/decode interleaving vs phased.

    The same seeded bursty traffic (``_sla_traffic``: priority classes
    interactive/standard/batch, work-unit arrivals) runs twice per cache
    dtype — once phased (``prefill_budget=None``: admission prefills the
    whole prompt synchronously, stalling every live decoder) and once
    interleaved (``prefill_budget = 3 x chunk``: pending prompts advance by
    chunk-aligned slices inside ``step()`` while decode proceeds).  Both
    runs go through :class:`ServeSupervisor` with priority+deadline
    admission ordering and ``arrival_unit="work_units"`` so the latency
    clock charges phased for the stall it actually causes.

    Gates (ABSOLUTE_FLOORS in benchmarks/run.py):

    * ``greedy_match_vs_phased{,_int8} == 1.0`` — budgeted slices land on
      the same chunk boundaries as monolithic prefill, so every token is
      bit-identical;
    * ``ttft_interactive_p99_improvement >= 1.0`` — the p99 TTFT proxy for
      the interactive class (nearest-rank, work-unit clock) at or below
      phased;
    * ``units_per_token_ratio >= 1.0`` — equal-or-better tokens/s proxy:
      (request_steps + prefill_chunks) per output token, identical by
      construction because interleaving re-slices the same chunks;
    * ``sweep_clean == 1.0`` — every ``close()`` drains the pool leak-free
      (pending mid-prefill rows included).

    A final interleaved run attaches a tight deadline to the batch request
    (abandon/timeout traffic): ``deadline_abandons`` reports how many
    requests the supervisor dropped at their deadline — informational, the
    parity runs carry no deadlines so the token streams stay comparable.
    """
    from repro.runtime.serve_loop import ServeSupervisor, latency_percentile

    budget = 3 * g["chunk"]
    traffic = {"bf16": _sla_traffic(cfg, g), "int8": _sla_traffic(cfg, g, n_bursts=1)}

    def _run(subs, prefill_budget, kv_dtype=None, deadlines=None):
        sess = PagedServingSession(
            model, params, num_pages=g["num_pages"], page_size=g["page"],
            block_k=g["block_k"], prefill_chunk=g["chunk"],
            prefill_budget=prefill_budget, kv_dtype=kv_dtype,
        )
        sup = ServeSupervisor(sess, gen_len=gen_len, arrival_unit="work_units")
        t0 = time.perf_counter()
        for i, (arr, prompt, pri) in enumerate(subs):
            sup.submit(prompt, priority=pri, arrival=arr,
                       deadline=(deadlines or {}).get(i))
        results = sup.run()
        jax.block_until_ready(sess.cache.pages)
        dt = time.perf_counter() - t0
        stats, recs, work = sup.stats(), sup.latency_records(), sess.work_stats()
        work["schedule_rebuilds"] = sess.scheduler_stats["rebuilds"]
        sweep = sess.close()
        clean = sweep["free_pages"] == g["num_pages"]
        return results, stats, recs, work, dt, clean, sup

    def _class_ttft(subs, recs):
        by_class = {0: [], 1: [], 2: []}
        for (_, _, pri), rec in zip(subs, recs):
            if rec["first_vt"] is not None:
                by_class[pri].append(rec["first_vt"] - rec["submit_vt"])
        return by_class

    res = {"requests": len(traffic["bf16"]), "prefill_budget": budget,
           "gen_len": gen_len}
    clean_all = True
    for dname, dtype in (("bf16", None), ("int8", "int8")):
        subs = traffic[dname]
        r_ph, s_ph, rec_ph, w_ph, dt_ph, c_ph, _ = _run(subs, None, dtype)
        r_il, s_il, rec_il, w_il, dt_il, c_il, _ = _run(subs, budget, dtype)
        clean_all = clean_all and c_ph and c_il
        suffix = "" if dname == "bf16" else "_int8"
        matches = sum(r_ph[i] == r_il[i] for i in r_ph if i in r_il)
        res[f"greedy_match_vs_phased{suffix}"] = matches / len(subs)
        if dname != "bf16":
            continue
        toks = sum(len(v) for v in r_il.values())
        ttft_ph, ttft_il = _class_ttft(subs, rec_ph), _class_ttft(subs, rec_il)
        upt_ph = (w_ph["request_steps"] + w_ph["prefill_chunks"]) / max(toks, 1)
        upt_il = (w_il["request_steps"] + w_il["prefill_chunks"]) / max(toks, 1)
        p99_ph = latency_percentile(ttft_ph[2], 99)
        p99_il = latency_percentile(ttft_il[2], 99)
        res.update({
            "tokens_out": toks,
            "tokens_per_s_paged": toks / max(dt_il, 1e-9),
            "tokens_per_s_phased": toks / max(dt_ph, 1e-9),
            "page_dmas_paged": w_il["page_dmas"],
            "page_dma_bytes_paged": w_il["page_dma_bytes"],
            "schedule_rebuilds": w_il["schedule_rebuilds"],
            "ttft_interactive_p50_phased": latency_percentile(ttft_ph[2], 50),
            "ttft_interactive_p50_interleaved": latency_percentile(ttft_il[2], 50),
            "ttft_interactive_p99_phased": p99_ph,
            "ttft_interactive_p99_interleaved": p99_il,
            "ttft_interactive_p99_improvement": p99_ph / max(p99_il, 1e-9),
            "ttft_standard_p99_phased": latency_percentile(ttft_ph[1], 99),
            "ttft_standard_p99_interleaved": latency_percentile(ttft_il[1], 99),
            "ttft_batch_p99_phased": latency_percentile(ttft_ph[0], 99),
            "ttft_batch_p99_interleaved": latency_percentile(ttft_il[0], 99),
            "tpot_units_p99_phased": s_ph["tpot_units_p99"],
            "tpot_units_p99_interleaved": s_il["tpot_units_p99"],
            "tpot_p99_improvement": (
                s_ph["tpot_units_p99"] / max(s_il["tpot_units_p99"], 1e-9)
            ),
            "prefill_stall_steps_phased": s_ph["prefill_stall_steps"],
            "prefill_stall_steps_interleaved": s_il["prefill_stall_steps"],
            "units_per_token_phased": upt_ph,
            "units_per_token_interleaved": upt_il,
            "units_per_token_ratio": upt_ph / max(upt_il, 1e-9),
        })
    # Abandon/timeout traffic: the batch long gets a deadline it cannot
    # meet, the supervisor must drop it at the deadline and still finish
    # everyone else (informational — parity runs carry no deadlines).
    subs = traffic["int8"]
    _, _, _, _, _, c_dl, sup = _run(subs, budget, None, deadlines={0: 3})
    clean_all = clean_all and c_dl
    res["deadline_abandons"] = len(sup.abandoned_idx)
    res["sweep_clean"] = float(clean_all)
    return res


def run(full: bool = False, smoke: bool = False) -> dict:
    tier = "full" if full else ("smoke" if smoke else "default")
    mode = "tpu" if _on_tpu() else "cpu-interpret"
    cfg, model, params, g = _build(tier)
    report = {"mode": mode, "tier": tier, "scenarios": {}}
    for name, shared in (("ragged", False), ("shared_prefix", True)):
        res = _serve_scenario(cfg, model, params, g, shared_prefix=shared)
        report["scenarios"][name] = res
        for k, v in sorted(res.items()):
            val = f"{v:.1f}" if isinstance(v, float) else v
            print(f"model_serve,{name},{k},{val}")
    sh = _sharded_scenario(cfg, model, params, g)
    report["scenarios"]["sharded"] = sh
    for k, v in sorted(sh.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,sharded,{k},{val}")
    res = _dtype_scenario(cfg, model, params, g)
    report["scenarios"]["int8_vs_bf16"] = res
    for k, v in sorted(res.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,int8_vs_bf16,{k},{val}")
    sp = _speculative_scenario(cfg, model, params, g)
    report["scenarios"]["speculative"] = sp
    for k, v in sorted(sp.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,speculative,{k},{val}")
    fr = _failure_recovery_scenario(cfg, model, params, g)
    report["scenarios"]["failure_recovery"] = fr
    for k, v in sorted(fr.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,failure_recovery,{k},{val}")
    mt = _multi_tenant_scenario(cfg, model, params, g)
    report["scenarios"]["multi_tenant"] = mt
    for k, v in sorted(mt.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,multi_tenant,{k},{val}")
    sla = _multi_tenant_sla_scenario(cfg, model, params, g)
    report["scenarios"]["multi_tenant_sla"] = sla
    for k, v in sorted(sla.items()):
        val = f"{v:.2f}" if isinstance(v, float) else v
        print(f"model_serve,multi_tenant_sla,{k},{val}")
    rag = report["scenarios"]["ragged"]
    print(
        f"model_serve,summary,read_reduction_vs_dense,"
        f"{rag['read_reduction_vs_dense']:.1f},schedules_per_step,"
        f"{(rag['schedule_rebuilds'] + rag['schedule_hits']) / max(rag['decode_steps'], 1):.2f}"
    )
    int8_ok = (
        res["dma_bytes_reduction_vs_bf16"] >= 1.9
        and res["greedy_match_vs_bf16"] == 1.0
    )
    print(
        f"model_serve,acceptance_int8,dma_bytes_reduction,"
        f"{res['dma_bytes_reduction_vs_bf16']:.2f},greedy_match,"
        f"{res['greedy_match_vs_bf16']:.2f},pass,{int(int8_ok)}"
    )
    sharded_ok = (
        sh["greedy_match_vs_single"] == 1.0 and sh["shard_imbalance"] <= 2.0
    )
    print(
        f"model_serve,acceptance_sharded,greedy_match,"
        f"{sh['greedy_match_vs_single']:.2f},shard_imbalance,"
        f"{sh['shard_imbalance']:.2f},pass,{int(sharded_ok)}"
    )
    spec_ok = (
        sp["accepted_tokens_per_step"] > 1.0
        and sp["greedy_match_vs_off"] == 1.0
        and sp["page_dma_bytes_per_accepted_token"]
        <= sp["page_dma_bytes_per_accepted_token_off"]
    )
    print(
        f"model_serve,acceptance_speculative,accepted_per_step,"
        f"{sp['accepted_tokens_per_step']:.2f},greedy_match,"
        f"{sp['greedy_match_vs_off']:.2f},dma_per_token_vs_off,"
        f"{sp['dma_per_token_vs_off']:.2f},pass,{int(spec_ok)}"
    )
    fr_ok = (
        fr["completed_fraction"] == 1.0
        and fr["greedy_match_vs_nofault"] == 1.0
        and fr["replay_mismatches"] == 0
        and fr["leaked_pages"] == 0
        and fr["suspends"] >= 1  # the injected loss must actually bite
    )
    print(
        f"model_serve,acceptance_failure_recovery,completed,"
        f"{fr['completed_fraction']:.2f},greedy_match,"
        f"{fr['greedy_match_vs_nofault']:.2f},replay_token_overhead,"
        f"{fr['replay_token_overhead']:.2f},pass,{int(fr_ok)}"
    )
    mt_ok = (
        mt["greedy_match_vs_off"] == 1.0
        and mt["greedy_match_vs_off_int8"] == 1.0
        and mt["dma_bytes_reduction_vs_off"] >= 2.0
        and mt["dma_bytes_reduction_vs_off_int8"] >= 2.0
        and mt["sweep_clean"] == 1.0
        and mt["sweep_clean_int8"] == 1.0
    )
    print(
        f"model_serve,acceptance_multi_tenant,dma_bytes_reduction,"
        f"{mt['dma_bytes_reduction_vs_off']:.2f},greedy_match,"
        f"{mt['greedy_match_vs_off']:.2f},hit_rate,"
        f"{mt['prefix_hit_rate']:.2f},pass,{int(mt_ok)}"
    )
    sla_ok = (
        sla["greedy_match_vs_phased"] == 1.0
        and sla["greedy_match_vs_phased_int8"] == 1.0
        and sla["ttft_interactive_p99_improvement"] >= 1.0
        and sla["units_per_token_ratio"] >= 1.0
        and sla["prefill_stall_steps_interleaved"] == 0
        and sla["sweep_clean"] == 1.0
    )
    print(
        f"model_serve,acceptance_multi_tenant_sla,ttft_p99_improvement,"
        f"{sla['ttft_interactive_p99_improvement']:.2f},greedy_match,"
        f"{sla['greedy_match_vs_phased']:.2f},units_per_token_ratio,"
        f"{sla['units_per_token_ratio']:.2f},stall_steps,"
        f"{sla['prefill_stall_steps_interleaved']},pass,{int(sla_ok)}"
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    run(full=args.full, smoke=args.smoke)


if __name__ == "__main__":
    main()
