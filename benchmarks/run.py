"""Benchmark harness: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip accuracy]

Sections:
  [T2]  arithmetic intensity (paper Table 2 / Fig. 1)
  [T3/T4] accuracy vs golden (paper Tables 3-4) + compensation ablations
  [T5]  kernel FLOPS-utilisation model (paper Table 5 / Fig. 10)
  [PAGED] paged vs contiguous decode latency + pool efficiency
  [ROOFLINE] per-(arch x shape x mesh) dry-run roofline table (assignment)

Each section prints CSV (``name,value,...``) so downstream tooling can diff.
"""

from __future__ import annotations

import argparse
import time


def section(name):
    print(f"\n===== [{name}] =====", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["accuracy", "intensity", "kernel", "roofline",
                             "paged"])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    args = ap.parse_args()

    t0 = time.time()
    if "intensity" not in args.skip:
        from benchmarks import arithmetic_intensity

        section("T2 arithmetic intensity")
        arithmetic_intensity.run()

    if "kernel" not in args.skip:
        from benchmarks import kernel_bench

        section("T5 kernel FU model")
        kernel_bench.run()

    if "accuracy" not in args.skip:
        from benchmarks import accuracy

        section("T3/T4 accuracy vs golden")
        accuracy.run()

    if "paged" not in args.skip:
        from benchmarks import paged_decode

        section("PAGED paged vs contiguous decode")
        paged_decode.run()

    if "roofline" not in args.skip:
        from benchmarks import roofline_bench

        section("ROOFLINE (from dry-run)")
        roofline_bench.run(dryrun_dir=args.dryrun_dir)

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
