"""Benchmark harness: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip accuracy]

Sections:
  [T2]  arithmetic intensity (paper Table 2 / Fig. 1)
  [T3/T4] accuracy vs golden (paper Tables 3-4) + compensation ablations
  [T5]  kernel FLOPS-utilisation model (paper Table 5 / Fig. 10)
  [PAGED] decode scheduling: work-queue vs padded grid, split-KV
  [ROOFLINE] per-(arch x shape x mesh) dry-run roofline table (assignment)

Each section prints CSV (``name,value,...``) so downstream tooling can diff.
The [PAGED] section additionally persists its per-scenario report
(tokens/s, ms/step, work items, rescale-skip rate) as ``BENCH_decode.json``
— the machine-readable perf trajectory diffed across PRs.
"""

from __future__ import annotations

import argparse
import json
import time


def section(name):
    print(f"\n===== [{name}] =====", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["accuracy", "intensity", "kernel", "roofline",
                             "paged"])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument(
        "--decode-json",
        default="BENCH_decode.json",
        help="where the [PAGED] section writes its machine-readable report",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="serving-scale [PAGED] geometry (TPU)",
    )
    args = ap.parse_args()

    t0 = time.time()
    if "intensity" not in args.skip:
        from benchmarks import arithmetic_intensity

        section("T2 arithmetic intensity")
        arithmetic_intensity.run()

    if "kernel" not in args.skip:
        from benchmarks import kernel_bench

        section("T5 kernel FU model")
        kernel_bench.run()

    if "accuracy" not in args.skip:
        from benchmarks import accuracy

        section("T3/T4 accuracy vs golden")
        accuracy.run()

    if "paged" not in args.skip:
        from benchmarks import paged_decode

        section("PAGED decode scheduling (queue vs padded)")
        report = paged_decode.run(full=args.full)
        with open(args.decode_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"paged_decode,json,{args.decode_json}")

    if "roofline" not in args.skip:
        from benchmarks import roofline_bench

        section("ROOFLINE (from dry-run)")
        roofline_bench.run(dryrun_dir=args.dryrun_dir)

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
