"""Benchmark harness: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip accuracy]

Sections:
  [T2]  arithmetic intensity (paper Table 2 / Fig. 1)
  [T3/T4] accuracy vs golden (paper Tables 3-4) + compensation ablations
  [T5]  kernel FLOPS-utilisation model (paper Table 5 / Fig. 10)
  [PAGED] decode scheduling: work-queue vs padded grid, split-KV,
          shared-prefix group batching
  [MODEL-SERVE] full-model serving: dense vs paged cache backend
          (tokens/s + page-DMA / row-read proxies, schedule reuse)
  [ROOFLINE] per-(arch x shape x mesh) dry-run roofline table (assignment)

Each section prints CSV (``name,value,...``) so downstream tooling can diff.
The [PAGED] section additionally persists its per-scenario report
(tokens/s, ms/step, work items, rescale-skip rate) as ``BENCH_decode.json``
— the machine-readable perf trajectory diffed across PRs — and **appends** a
compact summary of every run, keyed by git SHA, to ``BENCH_history.json``
(never overwritten: the longitudinal record survives baseline refreshes;
re-runs at the same SHA replace that SHA's entry so local iteration can't
bloat the trajectory file).

``--check-regression`` turns the [PAGED] section into a CI gate: before the
baseline file is overwritten, the freshly-measured scenarios are compared
against the committed report (same tier and mode only — a TPU run is never
judged against an interpret baseline) and the process exits non-zero on a
regression beyond ``--regression-tolerance`` (default 10%).  On TPU the
gated metric is tokens/s; in interpret mode (CI) it is the deterministic
work proxies — page DMAs, executed items, prefix DMA reduction — because
interpret wall time is Python-dispatch noise (see ``check_regression``).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import time


def section(name):
    print(f"\n===== [{name}] =====", flush=True)


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _summarize(report: dict) -> dict:
    """Compact per-run record for the longitudinal history file."""
    out = {
        "mode": report.get("mode"),
        "tier": report.get("tier"),
        "scenarios": {},
        "prefix_scenarios": {},
    }
    def pick(name, res, required, optional=()):
        # Loud on missing *required* metrics: a renamed/dropped benchmark
        # field must crash the run here, not silently vanish from the
        # history and un-gate its regression check downstream.
        missing = [k for k in required if k not in res]
        if missing:
            raise KeyError(
                f"benchmark scenario {name!r} stopped emitting gated "
                f"metrics {missing} — update the benchmark or this summary"
            )
        keys = tuple(required) + tuple(optional)
        return {k: res[k] for k in keys if k in res}

    for name, res in report.get("scenarios", {}).items():
        out["scenarios"][name] = pick(name, res, (
            "tokens_per_s_queue",
            "work_item_ratio",
            "page_dmas_queue",
            "page_dma_bytes_queue",
            "rescale_skip_rate",
        ))
    for name, res in report.get("prefix_scenarios", {}).items():
        out["prefix_scenarios"][name] = pick(name, res, (
            "tokens_per_s_shared",
            "tokens_per_s_unshared",
            "prefix_dma_reduction",
            "page_dmas_shared",
        ))
    if report.get("int8_scenarios"):
        out["int8_scenarios"] = {}
        for name, res in report["int8_scenarios"].items():
            out["int8_scenarios"][name] = pick(name, res, (
                "tokens_per_s_int8",
                "page_dma_bytes_bf16",
                "page_dma_bytes_int8",
                "dma_bytes_reduction_vs_bf16",
                "max_abs_diff_int8_vs_bf16",
            ))
    if report.get("model_serve"):
        out["model_serve"] = {}
        for name, res in report["model_serve"].items():
            # dense-twin and dtype-comparison metrics are optional: the
            # int8_vs_bf16 row has no dense session and the dense-vs-paged
            # rows no dtype twin — but what a row measures, it must keep.
            out["model_serve"][name] = pick(name, res, (
                "tokens_per_s_paged",
                "page_dmas_paged",
                "page_dma_bytes_paged",
                "schedule_rebuilds",
            ), optional=(
                "tokens_per_s_dense",
                "dma_bytes_reduction_vs_bf16",
                "greedy_match_vs_bf16",
                "read_reduction_vs_dense",
                "greedy_match_vs_single",
                "shard_imbalance",
                "accepted_tokens_per_step",
                "page_dma_bytes_per_accepted_token",
                "greedy_match_vs_off",
                "dma_per_token_vs_off",
                "completed_fraction",
                "greedy_match_vs_nofault",
                "replay_token_overhead",
                "replay_mismatches",
                "leaked_pages",
                "dma_bytes_reduction_vs_off",
                "dma_bytes_reduction_vs_off_int8",
                "greedy_match_vs_off_int8",
                "prefix_hit_rate",
                "prefix_tokens_reused_per_admission",
                "trie_evicted_pages",
                "sweep_clean",
                "greedy_match_vs_phased",
                "greedy_match_vs_phased_int8",
                "ttft_interactive_p99_phased",
                "ttft_interactive_p99_interleaved",
                "ttft_interactive_p99_improvement",
                "tpot_units_p99_phased",
                "tpot_units_p99_interleaved",
                "tpot_p99_improvement",
                "prefill_stall_steps_interleaved",
                "units_per_token_ratio",
                "deadline_abandons",
            ))
    return out


def append_history(report: dict, path: str) -> None:
    """Append (never overwrite) this run to the per-PR history, SHA-keyed."""
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
            if not isinstance(history, list):
                history = []
        except (json.JSONDecodeError, OSError):
            history = []  # corrupt history must not kill the benchmark
    entry = {
        "sha": _git_sha(),
        "time": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **_summarize(report),
    }
    # One entry per SHA, keep-latest: repeated local runs at the same
    # commit would otherwise append forever and swamp the per-PR diff.
    history = [h for h in history if h.get("sha") != entry["sha"]]
    history.append(entry)
    with open(path, "w") as f:
        json.dump(history, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"paged_decode,history,{path},entries,{len(history)}")


def merge_baseline_sections(report: dict, baseline_path: str) -> dict:
    """Carry like-for-like baseline sections a partial run didn't produce.

    ``--skip paged`` / ``--skip model-serve`` would otherwise overwrite the
    committed baseline with empty sections — and every later
    ``--check-regression`` against it would pass vacuously (missing
    reference metrics are skipped), silently un-gating that section.
    """
    if not os.path.exists(baseline_path):
        return report
    try:
        with open(baseline_path) as f:
            base = json.load(f)
    except (json.JSONDecodeError, OSError):
        return report
    if (base.get("tier"), base.get("mode")) != (
        report.get("tier"), report.get("mode")
    ):
        return report
    for key in ("scenarios", "prefix_scenarios", "int8_scenarios",
                "model_serve"):
        if not report.get(key) and base.get(key):
            report[key] = base[key]
            print(f"paged_decode,baseline_carryover,{key},from,{baseline_path}")
    return report


def check_regression(report: dict, baseline_path: str, tol: float) -> list:
    """Compare per-scenario perf against the committed baseline.

    Only like-for-like runs gate (same tier AND same mode); otherwise the
    check is skipped with a notice.  What gates depends on the mode:

    * ``tpu`` — **tokens/s**, the real measurement: fail when any scenario
      dropped more than ``tol``.
    * ``cpu-interpret`` (CI) — interpret-mode wall time is dominated by
      Python dispatch on a shared runner and jitters far beyond any usable
      threshold, so tokens/s prints *informationally* and the gate runs on
      the **deterministic work proxies** instead (page DMAs, executed work
      items, prefix DMA-reduction factor): fail when the schedule does
      more work than the baseline at equal output.  This is the ISSUE-3
      acceptance proxy, and it is exactly reproducible.

    Returns the list of failures.
    """
    if not os.path.exists(baseline_path):
        print(f"paged_decode,regression_check,skipped,no baseline at "
              f"{baseline_path}")
        return []
    with open(baseline_path) as f:
        base = json.load(f)
    if (base.get("tier"), base.get("mode")) != (
        report.get("tier"), report.get("mode")
    ):
        print(
            f"paged_decode,regression_check,skipped,baseline is "
            f"{base.get('tier')}/{base.get('mode')} vs current "
            f"{report.get('tier')}/{report.get('mode')}"
        )
        return []
    on_tpu = report.get("mode") == "tpu"
    failures = []
    # metric, section, lower-is-better, gated-in-this-mode
    checks = [
        ("scenarios", "tokens_per_s_queue", False, on_tpu),
        ("prefix_scenarios", "tokens_per_s_shared", False, on_tpu),
        ("scenarios", "page_dmas_queue", True, not on_tpu),
        ("scenarios", "grid_steps_queue", True, not on_tpu),
        # dtype-aware traffic: page-DMA *bytes* are the bandwidth proxy the
        # cache-dtype lever moves; gated so a storage-layout regression
        # (e.g. silently falling back to bf16) fails CI.
        ("scenarios", "page_dma_bytes_queue", True, not on_tpu),
        ("int8_scenarios", "page_dma_bytes_int8", True, not on_tpu),
        ("int8_scenarios", "dma_bytes_reduction_vs_bf16", False, not on_tpu),
        ("prefix_scenarios", "page_dmas_shared", True, not on_tpu),
        ("prefix_scenarios", "executed_items_shared", True, not on_tpu),
        ("prefix_scenarios", "prefix_dma_reduction", False, not on_tpu),
        # [MODEL-SERVE]: real tokens/s on TPU; deterministic schedule work
        # (page DMAs/bytes, rebuild count, dense-read reduction) in CI.
        ("model_serve", "tokens_per_s_paged", False, on_tpu),
        ("model_serve", "page_dmas_paged", True, not on_tpu),
        ("model_serve", "page_dma_bytes_paged", True, not on_tpu),
        ("model_serve", "dma_bytes_reduction_vs_bf16", False, not on_tpu),
        ("model_serve", "schedule_rebuilds", True, not on_tpu),
        ("model_serve", "read_reduction_vs_dense", False, not on_tpu),
        # [MODEL-SERVE] sharded row: exact greedy parity with the
        # single-host backend and the max/mean shard work split are both
        # deterministic, so they gate in CI like the other work proxies.
        ("model_serve", "greedy_match_vs_single", False, not on_tpu),
        ("model_serve", "shard_imbalance", True, not on_tpu),
        # [MODEL-SERVE] speculative row: acceptance rate, the per-accepted-
        # token DMA proxy, and exact greedy parity with --speculate off are
        # all deterministic in interpret mode, so they gate like the rest.
        ("model_serve", "accepted_tokens_per_step", False, not on_tpu),
        ("model_serve", "page_dma_bytes_per_accepted_token", True, not on_tpu),
        ("model_serve", "dma_per_token_vs_off", False, not on_tpu),
        ("model_serve", "greedy_match_vs_off", False, not on_tpu),
        # [MODEL-SERVE] failure_recovery row: completion and greedy parity
        # under a seeded shard loss are deterministic; replay overhead is
        # the recovery cost (lower is better — more overhead = regression).
        ("model_serve", "completed_fraction", False, not on_tpu),
        ("model_serve", "greedy_match_vs_nofault", False, not on_tpu),
        ("model_serve", "replay_token_overhead", True, not on_tpu),
        # [MODEL-SERVE] multi_tenant row: the prefix-trie DMA dedup factor
        # and the trie hit rate are deterministic work proxies — a trie
        # regression (missed matches, broken retention) shrinks both.
        ("model_serve", "dma_bytes_reduction_vs_off", False, not on_tpu),
        ("model_serve", "prefix_hit_rate", False, not on_tpu),
        # [MODEL-SERVE] multi_tenant_sla row: the interactive-class p99
        # TTFT improvement, per-token latency improvement, and interleaved
        # stall count are deterministic on the work-unit clock — a
        # scheduling regression (budget starvation, lost interleaving)
        # shrinks the improvements or revives stall steps (0 baseline:
        # any nonzero count fails outright).
        ("model_serve", "ttft_interactive_p99_improvement", False, not on_tpu),
        ("model_serve", "tpot_p99_improvement", False, not on_tpu),
        ("model_serve", "units_per_token_ratio", False, not on_tpu),
        ("model_serve", "prefill_stall_steps_interleaved", True, not on_tpu),
    ]
    for section_key, metric, lower_better, gated in checks:
        for name, res in report.get(section_key, {}).items():
            ref = base.get(section_key, {}).get(name, {}).get(metric)
            if ref is None or metric not in res:
                continue
            now = res[metric]
            if ref == 0:
                # A zero baseline is a real reference (e.g. a work proxy
                # that must stay at zero), not a missing one: no ratio
                # exists, so require equal-or-better outright.
                worse = now > 0 if lower_better else now < 0
                drop = float("inf") if worse else 0.0
            else:
                drop = (now - ref) / ref if lower_better else (ref - now) / ref
            bad = gated and drop > tol
            status = "fail" if bad else ("ok" if gated else "info")
            print(
                f"paged_decode,regression,{name},{metric},"
                f"baseline,{ref:.1f},now,{now:.1f},"
                f"worse,{100 * drop:.1f}%,{status}"
            )
            if bad:
                failures.append((name, metric, ref, now))
    return failures


# Hard acceptance floors checked against constants, not the committed
# baseline — a baseline refresh can ratchet a relative gate downward, but
# these invariants must hold outright in every deterministic run:
# speculation accepts at least the one token a plain step would (>= 1.0 by
# construction — below it the accounting itself is broken), stays
# token-exact vs --speculate off, and never costs more page-DMA bytes per
# accepted token than non-speculative decode (dma_per_token_vs_off is
# off/spec, so >= 1.0 means at-or-below baseline).
ABSOLUTE_FLOORS = [
    ("model_serve", "speculative", "accepted_tokens_per_step", 1.0),
    ("model_serve", "speculative", "greedy_match_vs_off", 1.0),
    ("model_serve", "speculative", "dma_per_token_vs_off", 1.0),
    # Fault tolerance is pass/fail, never relative: after a seeded shard
    # loss every request must complete (1.0), with tokens bit-identical to
    # the fault-free run (1.0).  A baseline refresh must not be able to
    # ratchet either below exact.
    ("model_serve", "failure_recovery", "completed_fraction", 1.0),
    ("model_serve", "failure_recovery", "greedy_match_vs_nofault", 1.0),
    # Prefix-trie admission must be invisible in the tokens (bit-exact
    # greedy vs trie-off, both cache dtypes), dominate the off path by at
    # least 2x in page-DMA bytes at equal output, and tear down leak-free
    # after eviction churn (sweep_clean is 1.0 only when every page of
    # every session returned to the free list).
    ("model_serve", "multi_tenant", "greedy_match_vs_off", 1.0),
    ("model_serve", "multi_tenant", "greedy_match_vs_off_int8", 1.0),
    ("model_serve", "multi_tenant", "dma_bytes_reduction_vs_off", 2.0),
    ("model_serve", "multi_tenant", "dma_bytes_reduction_vs_off_int8", 2.0),
    ("model_serve", "multi_tenant", "sweep_clean", 1.0),
    ("model_serve", "multi_tenant", "sweep_clean_int8", 1.0),
    # Token-budgeted interleaving must be invisible in the tokens (budgeted
    # chunk slices land on the same chunk boundaries as monolithic prefill,
    # both cache dtypes), beat phased admission on the interactive-class
    # p99 TTFT proxy at equal-or-better units/token (>= 1.0 means at or
    # below phased on both), and tear down leak-free with pending
    # mid-prefill rows in flight.
    ("model_serve", "multi_tenant_sla", "greedy_match_vs_phased", 1.0),
    ("model_serve", "multi_tenant_sla", "greedy_match_vs_phased_int8", 1.0),
    ("model_serve", "multi_tenant_sla", "ttft_interactive_p99_improvement", 1.0),
    ("model_serve", "multi_tenant_sla", "tpot_p99_improvement", 1.0),
    ("model_serve", "multi_tenant_sla", "units_per_token_ratio", 1.0),
    ("model_serve", "multi_tenant_sla", "sweep_clean", 1.0),
]


def check_floors(report: dict) -> list:
    """Gate ABSOLUTE_FLOORS (deterministic modes only; info on TPU, where
    greedy near-ties can shift acceptance run to run).  Returns failures."""
    gated = report.get("mode") != "tpu"
    failures = []
    for section_key, name, metric, floor in ABSOLUTE_FLOORS:
        res = report.get(section_key, {}).get(name, {})
        if metric not in res:
            continue
        now = res[metric]
        bad = gated and now < floor
        status = "fail" if bad else ("ok" if gated else "info")
        print(
            f"paged_decode,floor,{name},{metric},min,{floor},"
            f"now,{now:.2f},{status}"
        )
        if bad:
            failures.append((name, metric, floor, now))
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=["accuracy", "intensity", "kernel", "roofline",
                             "paged", "model-serve"])
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument(
        "--decode-json",
        default="BENCH_decode.json",
        help="where the [PAGED] section writes its machine-readable report",
    )
    ap.add_argument(
        "--history-json",
        default="BENCH_history.json",
        help="per-PR [PAGED] history (appended, keyed by git SHA)",
    )
    ap.add_argument(
        "--full",
        action="store_true",
        help="serving-scale [PAGED] geometry (TPU)",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny interpret-mode [PAGED] geometry (CI)",
    )
    ap.add_argument(
        "--check-regression",
        action="store_true",
        help="fail (exit 1) if [PAGED] tokens/s regressed vs the committed "
        "baseline (like-for-like tier+mode only)",
    )
    ap.add_argument("--regression-tolerance", type=float, default=0.10)
    args = ap.parse_args()

    t0 = time.time()
    if "intensity" not in args.skip:
        from benchmarks import arithmetic_intensity

        section("T2 arithmetic intensity")
        arithmetic_intensity.run()

    if "kernel" not in args.skip:
        from benchmarks import kernel_bench

        section("T5 kernel FU model")
        kernel_bench.run()

    if "accuracy" not in args.skip:
        from benchmarks import accuracy

        section("T3/T4 accuracy vs golden")
        accuracy.run()

    report = None
    if "paged" not in args.skip:
        from benchmarks import paged_decode

        section("PAGED decode scheduling (queue vs padded, shared prefix)")
        report = paged_decode.run(full=args.full, smoke=args.smoke)

    if "model-serve" not in args.skip:
        from benchmarks import model_serve

        section("MODEL-SERVE full-model decode (dense vs paged backend)")
        ms = model_serve.run(full=args.full, smoke=args.smoke)
        if report is None:  # [PAGED] skipped: still persist/gate this section
            report = {"mode": ms["mode"], "tier": ms["tier"],
                      "scenarios": {}, "prefix_scenarios": {}}
        report["model_serve"] = ms["scenarios"]

    if report is not None:
        failures = []
        if args.check_regression:
            # Gate against the *committed* baseline before overwriting it.
            failures = check_regression(
                report, args.decode_json, args.regression_tolerance
            )
            failures += check_floors(report)
        # Partial runs keep the baseline's other sections (gating integrity).
        report = merge_baseline_sections(report, args.decode_json)
        with open(args.decode_json, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"paged_decode,json,{args.decode_json}")
        append_history(report, args.history_json)
        if failures:
            names = ", ".join(f"{n}:{m}" for n, m, _, _ in failures)
            raise SystemExit(
                f"[PAGED]/[MODEL-SERVE] perf regression beyond "
                f"{100 * args.regression_tolerance:.0f}% vs "
                f"{args.decode_json}: {names}"
            )

    if "roofline" not in args.skip:
        from benchmarks import roofline_bench

        section("ROOFLINE (from dry-run)")
        roofline_bench.run(dryrun_dir=args.dryrun_dir)

    print(f"\nbenchmarks done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
