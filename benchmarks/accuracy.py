"""Paper Tables 3 & 4 reproduction: relative Frobenius error vs fp64 golden.

Golden: fp64 full-softmax attention on CPU (paper's Golden).
Base:   Algorithm 1 (FlashAttention, BF16 matmuls, FP32 accumulation).
AMLA:   Algorithm 2 (MUL-by-ADD rescale + Appendix-A compensation).
Plus two ablations the paper motivates: AMLA without error compensation and
the exact-FP-multiply variant of the same power-of-two update.

Settings follow the paper: context 8K, typical MLA decode geometry
(G=128, Dk=576, Dv=512), BF16 inputs, averaged over N samples.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.amla import flash_attention_amla
from repro.core.flash import flash_attention_base

G, S, DK, DV = 128, 8192, 576, 512
N_SAMPLES = 10  # paper uses 100; 10 keeps CPU wall-time sane (std < 3%)


def golden_attention(q, k, v, scale):
    q, k, v = [np.asarray(x, np.float64) for x in (q, k, v)]
    s = q @ k.T * scale
    p = np.exp(s - s.max(-1, keepdims=True))
    return (p / p.sum(-1, keepdims=True)) @ v


def rel_err(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-10)


def _sample(dist, param, seed):
    rng = np.random.default_rng(seed)
    if dist == "normal":
        draw = lambda shape: rng.normal(0.0, param, shape)
    else:
        draw = lambda shape: rng.uniform(-param, param, shape)
    cast = lambda x: jnp.asarray(x, jnp.bfloat16).astype(jnp.float32)
    return cast(draw((G, DK))), cast(draw((S, DK))), cast(draw((S, DV)))


def run_distribution(dist, param):
    scale = 1.0 / np.sqrt(DK)
    errs = {"base": [], "amla": [], "amla_nocomp": [], "amla_fpmul": []}
    for i in range(N_SAMPLES):
        q, k, v = _sample(dist, param, seed=1000 * i + int(param * 7))
        g = golden_attention(q, k, v, scale)
        errs["base"].append(rel_err(flash_attention_base(q, k, v, scale=scale), g))
        errs["amla"].append(rel_err(flash_attention_amla(q, k, v, scale=scale), g))
        errs["amla_nocomp"].append(
            rel_err(
                flash_attention_amla(
                    q, k, v, scale=scale, error_compensation=False
                ),
                g,
            )
        )
        errs["amla_fpmul"].append(
            rel_err(flash_attention_amla(q, k, v, scale=scale, int_add=False), g)
        )
    return {k: float(np.mean(v)) for k, v in errs.items()}


def run(csv_out=print):
    csv_out("table,distribution,base,amla,amla_nocomp,amla_fpmul")
    rows = []
    for sigma in [1, 2, 3, 4, 5, 10]:  # N(0, sigma^2): paper Table 3
        r = run_distribution("normal", float(sigma))
        rows.append((f"T3,N(0_{sigma * sigma})", r))
    for a in [1, 3, 5, 10, 20, 60]:  # U(-a, a): paper Table 4
        r = run_distribution("uniform", float(a))
        rows.append((f"T4,U(-{a}_{a})", r))
    for name, r in rows:
        csv_out(
            f"{name},{r['base']:.3e},{r['amla']:.3e},"
            f"{r['amla_nocomp']:.3e},{r['amla_fpmul']:.3e}"
        )
    return rows


if __name__ == "__main__":
    run()
