"""Paged vs contiguous MLA decode: latency + memory-efficiency comparison.

    PYTHONPATH=src python -m benchmarks.paged_decode [--full]

Two numbers matter for serving:

* **step latency** — the paged kernel's block-table gather must not cost
  wall-clock vs the contiguous kernel (on TPU the gather rides the grid
  pipeline's prefetch; in interpret mode on CPU both paths pay the same
  python-level tax, so treat CPU ratios as smoke only).
* **pool efficiency** — contiguous slots reserve ``max_len`` rows per
  request; pages waste at most ``page_size - 1`` rows per request.  The CSV
  reports both so the ROADMAP's serving claims are backed by a number.

Output is CSV (``name,value,...``) like every other benchmarks/ section.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.runtime.kv_cache import PagedKVCache


def _on_tpu() -> bool:
    return any(d.platform == "tpu" for d in jax.devices())


def _time(fn, iters: int) -> float:
    fn()  # compile / warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def run(full: bool = False) -> None:
    interpret = not _on_tpu()
    if full:
        b, hq, dk, dv, page, max_len = 8, 128, 576, 512, 128, 8192
        iters = 20
    else:  # interpret-friendly smoke geometry
        b, hq, dk, dv, page, max_len = 2, 8, 576, 512, 128, 1024
        iters = 2

    rng = np.random.default_rng(0)
    kv_lens = [int(x) for x in rng.integers(max_len // 4, max_len, b)]
    scale = 1.0 / dk**0.5
    q = jnp.asarray(rng.normal(0, 0.3, (b, 1, hq, dk)), jnp.bfloat16)
    c = jnp.asarray(rng.normal(0, 0.3, (b, max_len, dk)), jnp.bfloat16)
    kv_len = jnp.asarray(kv_lens, jnp.int32)

    kv = PagedKVCache(
        num_pages=sum(-(-l // page) for l in kv_lens) + 1,
        page_size=page,
        width=dk,
    )
    for rid, l in enumerate(kv_lens):
        kv.alloc(rid)
        kv.append(rid, c[rid, :l])
    bt, _ = kv.block_table(list(range(b)))
    bt = jnp.asarray(bt)

    def contiguous():
        return ops.mla_decode(
            q, c, d_v=dv, scale=scale, kv_len=kv_len, interpret=interpret
        )

    def paged():
        return ops.mla_decode_paged(
            q, kv.pages, bt, kv_len, d_v=dv, scale=scale, interpret=interpret
        )

    max_abs = float(jnp.max(jnp.abs(paged() - contiguous())))
    ms_contig = _time(contiguous, iters)
    ms_paged = _time(paged, iters)

    # memory: rows resident on device to serve this batch
    contig_rows = b * max_len
    paged_rows = kv.num_pages * page
    used_rows = sum(kv_lens)

    mode = "tpu" if not interpret else "cpu-interpret"
    print(f"paged_decode,mode,{mode},b,{b},hq,{hq},page,{page}")
    print(f"paged_decode,max_abs_diff,{max_abs:.3e}")
    print(
        f"paged_decode,ms_contiguous,{ms_contig:.3f},ms_paged,{ms_paged:.3f},"
        f"ratio,{ms_paged / ms_contig:.3f}"
    )
    print(
        f"paged_decode,rows_contiguous,{contig_rows},rows_paged,{paged_rows},"
        f"rows_used,{used_rows},pool_util,{used_rows / paged_rows:.3f},"
        f"contig_util,{used_rows / contig_rows:.3f}"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--full",
        action="store_true",
        help="serving-scale geometry (TPU); default is an interpret-safe smoke",
    )
    args = ap.parse_args()
    run(full=args.full)
