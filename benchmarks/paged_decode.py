"""Paged decode scheduling benchmark: work-queue vs padded grid, split-KV.

    PYTHONPATH=src python -m benchmarks.paged_decode [--smoke | --full]

Three numbers matter for serving, and each gets a scenario matrix
(uniform / ragged / long-context straggler batches):

* **work items** — the padded ``(B, W)`` grid pays one page-sized grid
  step per logical table slot of the *longest* request; the flat work
  queue (kernels/decode_schedule) pays one §4.2-block-sized step per KV
  block that intersects ``kv_len``.  ``work_item_ratio`` compares grid
  steps (hierarchical tiling + compaction together, the acceptance gate:
  >= 1.5x on the ragged scenario); ``compaction_ratio`` is the
  granularity-matched view (padded page slots vs live pages), isolating
  pure schedule compaction from the bigger-step win.
* **step latency / tokens/s** — measured per scheduler (on CPU interpret
  both pay a python-level tax, treat as smoke; TPU via --full is the real
  measurement).
* **rescale-skip rate** — the fraction of §4.2-block AMLA updates whose
  MUL-by-ADD increment is exactly zero (the paper's skipped [V2]); tracked
  per scenario so scheduling changes can't silently regress the numerics
  win.

``run()`` returns a JSON-able dict; ``benchmarks/run.py`` persists it as
``BENCH_decode.json`` — the cross-PR perf trajectory.  Output here is CSV
(``name,value,...``) like every other benchmarks/ section.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.amla import rescale_skip_rate
from repro.kernels import ops
from repro.kernels.decode_schedule import (
    build_prefix_schedule,
    build_schedule,
    padded_grid_items,
    prefix_queue_grid_items,
    queue_grid_items,
)
from repro.runtime.kv_cache import CacheSpec, PagedKVCache


def _on_tpu() -> bool:
    return any(d.platform == "tpu" for d in jax.devices())


def _time(fn, iters: int) -> float:
    """Min-of-iters wall time in ms (min, not mean: the regression gate
    compares runs across processes/machines, and the minimum is the
    standard noise-robust estimate of the true cost)."""
    fn()  # compile / warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def _geometry(tier: str) -> dict:
    """Scenario matrix per tier.  kv_lens are per-request context lengths;
    the ragged tier-`full` scenario is the ISSUE-2 acceptance geometry
    (B=8, kv_len in [256, 16384]).  ``prefix_scenarios`` are fork families
    ``(group_size, prefix_len, mean suffix_len)``: group sizes {1, 4, 16}
    (ISSUE-3 acceptance) crossed with prefix:suffix ratios — the shared
    prefix dominates at 16:1 (system-prompt / n-best traffic), 4:1 keeps a
    meaningful per-request tail."""
    if tier == "full":  # serving scale (TPU)
        g = dict(hq=128, dk=576, dv=512, page=128, block_k=512, iters=20)
        rng = np.random.default_rng(7)
        g["scenarios"] = {
            "uniform": [8192] * 8,
            "ragged": [int(x) for x in rng.integers(256, 16384, 8)],
            "straggler": [1024] * 7 + [32768],
        }
        g["prefix_scenarios"] = {
            "g1_p16": (1, 8192, 512),
            "g4_p16": (4, 8192, 512),
            "g4_p4": (4, 2048, 512),
            "g16_p16": (16, 8192, 512),
            "g16_p4": (16, 2048, 512),
        }
    elif tier == "smoke":  # CI: interpret-mode, tiny shapes
        # iters=5 + min-of-iters timing: the CI regression gate compares
        # these numbers across runs, so single-shot noise is not acceptable.
        g = dict(hq=4, dk=128, dv=128, page=32, block_k=128, iters=5)
        g["scenarios"] = {
            "uniform": [96, 96, 96],
            "ragged": [16, 250, 60, 130],
            "straggler": [20, 20, 20, 300],
        }
        g["prefix_scenarios"] = {
            "g1_p8": (1, 260, 33),
            "g4_p8": (4, 260, 33),
            "g16_p8": (16, 260, 33),
        }
    else:  # default: interpret-friendly but paper-geometry rows
        g = dict(hq=8, dk=576, dv=512, page=128, block_k=512, iters=2)
        rng = np.random.default_rng(7)
        g["scenarios"] = {
            "uniform": [1024] * 4,
            "ragged": [int(x) for x in rng.integers(128, 2048, 4)],
            "straggler": [256] * 3 + [2048],
        }
        g["prefix_scenarios"] = {
            "g1_p16": (1, 1024, 64),
            "g4_p16": (4, 1024, 64),
            "g4_p4": (4, 1024, 256),
            "g16_p16": (16, 1024, 64),
        }
    return g


def _measure_rescale_skip(q_rows, c, kv_lens, scale, block_k) -> float:
    """Mean per-request fraction of §4.2-block updates whose AMLA increment
    is zero (running max stays inside one power-of-two bin)."""
    rates = []
    for r, l in enumerate(kv_lens):
        nb = -(-int(l) // block_k)
        if nb < 2:  # no transitions to measure
            continue
        qr = np.asarray(q_rows[r], np.float32)
        cr = np.asarray(c[r, :l], np.float32)
        m = np.full((qr.shape[0],), -1.0e5, np.float32)
        trace = []
        for i in range(nb):
            s = (qr @ cr[i * block_k : min((i + 1) * block_k, l)].T) * scale
            m = np.maximum(m, s.max(axis=-1))
            trace.append(m.copy())
        rates.append(float(rescale_skip_rate(jnp.asarray(np.stack(trace)))))
    return float(np.mean(rates)) if rates else 1.0


def _run_scenario(name, kv_lens, *, hq, dk, dv, page, block_k, iters,
                  interpret, num_splits) -> dict:
    b = len(kv_lens)
    max_len = max(kv_lens)
    rng = np.random.default_rng(0)
    scale = 1.0 / dk**0.5
    q = jnp.asarray(rng.normal(0, 0.3, (b, 1, hq, dk)), jnp.bfloat16)
    c = jnp.asarray(rng.normal(0, 0.3, (b, max_len, dk)), jnp.bfloat16)
    kv_len = jnp.asarray(kv_lens, jnp.int32)

    kv = PagedKVCache(
        num_pages=sum(-(-l // page) for l in kv_lens) + 1,
        page_size=page,
        width=dk,
    )
    for rid, l in enumerate(kv_lens):
        kv.alloc(rid)
        kv.append(rid, c[rid, :l])
    bt, _ = kv.block_table(list(range(b)))
    bt = jnp.asarray(bt)
    w = bt.shape[1]

    schedule = build_schedule(kv_lens, block_k=block_k, num_splits=num_splits)
    padded_work = padded_grid_items(kv_lens, w, page)
    queue_work = queue_grid_items(schedule, kv_lens, page)

    def contiguous():
        return ops.mla_decode(
            q, c, d_v=dv, scale=scale, kv_len=kv_len, interpret=interpret
        )

    def padded():
        return ops.mla_decode_paged(
            q, kv.pages, bt, kv_len, d_v=dv, scale=scale,
            interpret=interpret, scheduler="padded",
        )

    def queue():
        return ops.mla_decode_paged(
            q, kv.pages, bt, kv_len, d_v=dv, scale=scale,
            interpret=interpret, scheduler="queue",
            block_k=block_k, schedule=schedule,
        )

    max_abs_queue = float(jnp.max(jnp.abs(queue() - contiguous())))
    max_abs_padded = float(jnp.max(jnp.abs(padded() - contiguous())))
    ms_padded = _time(padded, iters)
    ms_queue = _time(queue, iters)
    skip = _measure_rescale_skip(
        np.asarray(q[:, 0], np.float32), c, kv_lens, scale, block_k
    )

    # memory: rows resident on device to serve this batch
    paged_rows = kv.num_pages * page
    used_rows = sum(kv_lens)

    return {
        "b": b,
        "kv_lens": list(map(int, kv_lens)),
        "table_width": int(w),
        "ms_per_step_padded": ms_padded,
        "ms_per_step_queue": ms_queue,
        "tokens_per_s_padded": b / (ms_padded / 1e3),
        "tokens_per_s_queue": b / (ms_queue / 1e3),
        "rescale_skip_rate": skip,
        "grid_steps_padded": padded_work["grid_steps"],
        "grid_steps_queue": queue_work["grid_steps"],
        "executed_items_queue": queue_work["executed_items"],
        "page_dmas_padded": padded_work["page_dmas"],
        "page_dmas_queue": queue_work["page_dmas"],
        # dtype-aware traffic: DMA count x bytes one page moves at this
        # pool's storage layout (decode MLA is bandwidth-bound, so *bytes*
        # — not counts — are what the cache-dtype lever changes).
        "page_dma_bytes_padded": padded_work["page_dmas"]
        * kv.spec.bytes_per_page(page, dk),
        "page_dma_bytes_queue": queue_work["page_dmas"]
        * kv.spec.bytes_per_page(page, dk),
        # grid-step ratio: fewer, bigger steps (§4.2 block granularity vs
        # page granularity) *and* schedule compaction
        "work_item_ratio": padded_work["grid_steps"]
        / max(queue_work["grid_steps"], 1),
        # granularity-matched: page slots walked by the padded grid vs live
        # pages touched by the queue — pure compaction, no tiling credit
        "compaction_ratio": padded_work["page_slots"]
        / max(queue_work["live_pages"], 1),
        "num_splits": num_splits,
        "max_abs_diff_vs_contiguous_queue": max_abs_queue,
        "max_abs_diff_vs_contiguous_padded": max_abs_padded,
        "pool_util": used_rows / paged_rows,
    }


def _run_prefix_scenario(name, group_size, prefix_len, suffix_mean, *,
                         hq, dk, dv, page, block_k, iters, interpret) -> dict:
    """Fork family: one parent prefix aliased by ``group_size`` members with
    ragged suffixes; shared-prefix path vs the plain per-request queue."""
    rng = np.random.default_rng(3)
    scale = 1.0 / dk**0.5
    suffix_lens = [
        int(x) for x in rng.integers(max(suffix_mean // 2, 1),
                                     2 * suffix_mean, group_size)
    ]
    num_pages = (
        -(-prefix_len // page) + group_size
        + sum(-(-n // page) for n in suffix_lens) + 2
    )
    kv = PagedKVCache(num_pages=num_pages, page_size=page, width=dk)
    kv.alloc(0)
    kv.append(0, jnp.asarray(rng.normal(0, 0.3, (prefix_len, dk)),
                             jnp.bfloat16))
    for rid in range(1, group_size):
        kv.fork(0, rid, prefix_len)
    for rid, n in enumerate(suffix_lens):
        kv.append(rid, jnp.asarray(rng.normal(0, 0.3, (n, dk)),
                                   jnp.bfloat16))
    rids = list(range(group_size))
    bt_np, kv_lens = kv.block_table(rids)
    bt = jnp.asarray(bt_np)
    kv_len = jnp.asarray(kv_lens)
    q = jnp.asarray(rng.normal(0, 0.3, (group_size, 1, hq, dk)),
                    jnp.bfloat16)

    ps = build_prefix_schedule(kv_lens, bt_np, page_size=page,
                               block_k=block_k)
    plain = build_schedule(kv_lens, block_k=block_k)
    shared_work = prefix_queue_grid_items(ps, kv_lens, page)
    plain_work = queue_grid_items(plain, kv_lens, page)

    def shared():
        return ops.mla_decode_paged(
            q, kv.pages, bt, kv_len, d_v=dv, scale=scale,
            interpret=interpret, block_k=block_k, schedule=ps,
        )

    def unshared():
        return ops.mla_decode_paged(
            q, kv.pages, bt, kv_len, d_v=dv, scale=scale,
            interpret=interpret, block_k=block_k, schedule=plain,
        )

    max_abs = float(jnp.max(jnp.abs(shared() - unshared())))
    ms_shared = _time(shared, iters)
    ms_unshared = _time(unshared, iters)
    pdma = shared_work["prefix_page_dmas"]
    return {
        "group_size": group_size,
        "prefix_len": prefix_len,
        "suffix_lens": suffix_lens,
        "num_groups": shared_work["num_groups"],
        "ms_per_step_shared": ms_shared,
        "ms_per_step_unshared": ms_unshared,
        "tokens_per_s_shared": group_size / (ms_shared / 1e3),
        "tokens_per_s_unshared": group_size / (ms_unshared / 1e3),
        "page_dmas_shared": shared_work["page_dmas"],
        "page_dmas_unshared": plain_work["page_dmas"],
        "prefix_page_dmas": pdma,
        "unshared_prefix_page_dmas": shared_work["unshared_prefix_page_dmas"],
        # the headline: shared prefix pages fetched once per group
        "prefix_dma_reduction": (
            shared_work["unshared_prefix_page_dmas"] / pdma if pdma else 1.0
        ),
        "executed_items_shared": shared_work["executed_items"],
        "executed_items_unshared": plain_work["executed_items"],
        "max_abs_diff_shared_vs_unshared": max_abs,
    }


def _run_int8_scenario(name, kv_lens, *, hq, dk, dv, page, block_k, iters,
                       interpret) -> dict:
    """Int8-vs-bf16 storage row: the same ragged batch decoded through a
    bf16 pool and through an int8+scales pool (fused in-pipeline dequant).

    The headline is ``dma_bytes_reduction_vs_bf16``: identical schedules
    fetch identical page *counts*, but each int8 page moves about half the
    bytes (ISSUE-5 acceptance: >= 1.9x) — with |int8 − bf16| <= 3e-2
    fp32-combined parity riding along.
    """
    b = len(kv_lens)
    rng = np.random.default_rng(0)
    scale = 1.0 / dk**0.5
    q = jnp.asarray(rng.normal(0, 0.3, (b, 1, hq, dk)), jnp.bfloat16)
    num_pages = sum(-(-l // page) for l in kv_lens) + 1
    pools = {
        "bf16": PagedKVCache(num_pages=num_pages, page_size=page, width=dk),
        "int8": PagedKVCache(num_pages=num_pages, page_size=page, width=dk,
                             spec=CacheSpec(dtype=jnp.int8)),
    }
    for rid, l in enumerate(kv_lens):
        data = rng.normal(0, 0.3, (l, dk)).astype(np.float32)
        for kv in pools.values():
            kv.alloc(rid)
            kv.append(rid, data)
    kv_len = jnp.asarray(kv_lens, jnp.int32)
    schedule = build_schedule(kv_lens, block_k=block_k)
    work = queue_grid_items(schedule, kv_lens, page)

    def decode(kv):
        bt, _ = kv.block_table(list(range(b)))
        return ops.mla_decode_paged(
            q, kv.pages, jnp.asarray(bt), kv_len, kv_scales=kv.scales,
            d_v=dv, scale=scale, interpret=interpret, block_k=block_k,
            schedule=schedule,
        )

    max_abs = float(jnp.max(jnp.abs(decode(pools["int8"])
                                    - decode(pools["bf16"]))))
    ms = {k: _time(lambda kv=kv: decode(kv), iters)
          for k, kv in pools.items()}
    dma_bytes = {
        k: work["page_dmas"] * kv.spec.bytes_per_page(page, dk)
        for k, kv in pools.items()
    }
    return {
        "b": b,
        "kv_lens": list(map(int, kv_lens)),
        "ms_per_step_bf16": ms["bf16"],
        "ms_per_step_int8": ms["int8"],
        "tokens_per_s_int8": b / (ms["int8"] / 1e3),
        "page_dmas_queue": work["page_dmas"],
        "page_dma_bytes_bf16": dma_bytes["bf16"],
        "page_dma_bytes_int8": dma_bytes["int8"],
        "dma_bytes_reduction_vs_bf16": dma_bytes["bf16"] / dma_bytes["int8"],
        "max_abs_diff_int8_vs_bf16": max_abs,
    }


def run(full: bool = False, smoke: bool = False, num_splits: int = 2) -> dict:
    interpret = not _on_tpu()
    tier = "full" if full else ("smoke" if smoke else "default")
    g = _geometry(tier)
    mode = "tpu" if not interpret else "cpu-interpret"

    report = {
        "bench": "paged_decode",
        "mode": mode,
        "tier": tier,
        "hq": g["hq"],
        "page_size": g["page"],
        "block_k": g["block_k"],
        "scenarios": {},
    }
    print(
        f"paged_decode,mode,{mode},tier,{tier},hq,{g['hq']},"
        f"page,{g['page']},block_k,{g['block_k']}"
    )
    for name, kv_lens in g["scenarios"].items():
        res = _run_scenario(
            name,
            kv_lens,
            hq=g["hq"],
            dk=g["dk"],
            dv=g["dv"],
            page=g["page"],
            block_k=g["block_k"],
            iters=g["iters"],
            interpret=interpret,
            num_splits=num_splits,
        )
        report["scenarios"][name] = res
        print(
            f"paged_decode,scenario,{name},b,{res['b']},"
            f"ms_padded,{res['ms_per_step_padded']:.3f},"
            f"ms_queue,{res['ms_per_step_queue']:.3f},"
            f"tokens_per_s_queue,{res['tokens_per_s_queue']:.1f}"
        )
        print(
            f"paged_decode,scenario,{name},"
            f"grid_steps_padded,{res['grid_steps_padded']},"
            f"grid_steps_queue,{res['grid_steps_queue']},"
            f"work_item_ratio,{res['work_item_ratio']:.2f},"
            f"compaction_ratio,{res['compaction_ratio']:.2f},"
            f"page_dmas_padded,{res['page_dmas_padded']},"
            f"page_dmas_queue,{res['page_dmas_queue']},"
            f"page_dma_bytes_queue,{res['page_dma_bytes_queue']}"
        )
        print(
            f"paged_decode,scenario,{name},"
            f"rescale_skip_rate,{res['rescale_skip_rate']:.3f},"
            f"max_abs_queue,{res['max_abs_diff_vs_contiguous_queue']:.3e},"
            f"max_abs_padded,{res['max_abs_diff_vs_contiguous_padded']:.3e}"
        )
    report["prefix_scenarios"] = {}
    for name, (gsz, plen, smean) in g.get("prefix_scenarios", {}).items():
        res = _run_prefix_scenario(
            name, gsz, plen, smean,
            hq=g["hq"], dk=g["dk"], dv=g["dv"], page=g["page"],
            block_k=g["block_k"], iters=g["iters"], interpret=interpret,
        )
        report["prefix_scenarios"][name] = res
        print(
            f"paged_decode,prefix_scenario,{name},group,{gsz},"
            f"prefix_len,{plen},"
            f"ms_shared,{res['ms_per_step_shared']:.3f},"
            f"ms_unshared,{res['ms_per_step_unshared']:.3f},"
            f"tokens_per_s_shared,{res['tokens_per_s_shared']:.1f}"
        )
        print(
            f"paged_decode,prefix_scenario,{name},"
            f"prefix_dma_reduction,{res['prefix_dma_reduction']:.2f},"
            f"page_dmas_shared,{res['page_dmas_shared']},"
            f"page_dmas_unshared,{res['page_dmas_unshared']},"
            f"items_shared,{res['executed_items_shared']},"
            f"items_unshared,{res['executed_items_unshared']},"
            f"max_abs,{res['max_abs_diff_shared_vs_unshared']:.3e}"
        )

    # int8-vs-bf16 storage row: same ragged batch, both cache dtypes.
    report["int8_scenarios"] = {}
    res = _run_int8_scenario(
        "ragged_int8", g["scenarios"]["ragged"],
        hq=g["hq"], dk=g["dk"], dv=g["dv"], page=g["page"],
        block_k=g["block_k"], iters=g["iters"], interpret=interpret,
    )
    report["int8_scenarios"]["ragged_int8"] = res
    print(
        f"paged_decode,int8_scenario,ragged_int8,b,{res['b']},"
        f"ms_bf16,{res['ms_per_step_bf16']:.3f},"
        f"ms_int8,{res['ms_per_step_int8']:.3f},"
        f"page_dma_bytes_bf16,{res['page_dma_bytes_bf16']},"
        f"page_dma_bytes_int8,{res['page_dma_bytes_int8']},"
        f"dma_bytes_reduction,{res['dma_bytes_reduction_vs_bf16']:.2f},"
        f"max_abs_int8_vs_bf16,{res['max_abs_diff_int8_vs_bf16']:.3e}"
    )
    # ISSUE-5 acceptance: >= 1.9x byte reduction at <= 3e-2 parity.
    int8_ok = (
        res["dma_bytes_reduction_vs_bf16"] >= 1.9
        and res["max_abs_diff_int8_vs_bf16"] <= 3e-2
    )
    print(
        f"paged_decode,acceptance_int8_bytes,"
        f"{res['dma_bytes_reduction_vs_bf16']:.2f},target,1.9,"
        f"parity,{res['max_abs_diff_int8_vs_bf16']:.3e},pass,{int(int8_ok)}"
    )

    ragged = report["scenarios"]["ragged"]
    ok = ragged["work_item_ratio"] >= 1.5
    print(
        f"paged_decode,acceptance_ragged_work_ratio,"
        f"{ragged['work_item_ratio']:.2f},"
        f"compaction_ratio,{ragged['compaction_ratio']:.2f},pass,{int(ok)}"
    )
    # ISSUE-3 acceptance: shared-prefix DMA dedup ~G x at group size G
    # (within 10%), and the shared path does strictly less work (the
    # interpret-mode tokens/s proxy) at group size >= 4.
    for name, res in report["prefix_scenarios"].items():
        gsz = res["group_size"]
        if gsz < 4:
            continue
        dma_ok = abs(res["prefix_dma_reduction"] - gsz) / gsz <= 0.10
        work_ok = (
            res["executed_items_shared"] < res["executed_items_unshared"]
            and res["page_dmas_shared"] < res["page_dmas_unshared"]
        )
        print(
            f"paged_decode,acceptance_prefix,{name},"
            f"dma_reduction,{res['prefix_dma_reduction']:.2f},"
            f"target,{gsz},pass,{int(dma_ok and work_ok)}"
        )
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--full",
        action="store_true",
        help="serving-scale geometry (TPU); default is an interpret-safe tier",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny interpret-mode shapes for CI (keeps benchmark code green)",
    )
    ap.add_argument("--num-splits", type=int, default=2)
    args = ap.parse_args()
    run(full=args.full, smoke=args.smoke, num_splits=args.num_splits)
