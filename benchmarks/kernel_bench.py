"""Paper Table 5 / Fig. 10 analogue: AMLA decode-kernel FLOPS utilisation.

No TPU is attached (CPU container), so wall-clock FU cannot be measured.
Following the assignment's roofline methodology we report, per (S_q, S_k)
point of the paper's grid (B=96, 128 q-heads, kv-heads=1, BF16):

  model_gflops      useful kernel FLOPs = 2*B*G*S_k*(Dk+Dv)
  roofline_us       time at 100% of 197 TFLOP/s on one chip
  fu_structural     useful / issued MXU FLOPs: block padding (ceil to 512
                    keys) and MXU tile padding of the 576-wide latent+rope
                    K-dim (576 -> 5x128 = 640 lanes)
  fu_modeled        fu_structural * steady/(steady + preload): the Preload
                    Pipeline (paper §4.1) resolves 2 stages up front; the
                    warm-up is amortised over ceil(S_k/512) steady cycles,
                    reproducing the paper's FU-vs-S_k ramp
  est_us            roofline_us / fu_modeled (the Table-5 'duration' analogue)
  skip_rate         fraction of KV blocks whose AMLA rescale increment is
                    exactly zero (measured on N(0,1) inputs) — the TPU-
                    specific [V2]-elimination beyond the paper's GM traffic
                    argument (Base rescales on 100% of blocks)

These are models over the compiled/derived kernel structure, not hardware
measurements; EXPERIMENTS.md discusses them against the paper's Table 5.
"""

from __future__ import annotations

import numpy as np

from repro.core import numerics
from repro.roofline.analysis import PEAK_FLOPS

B, HEADS, DK, DV = 96, 128, 576, 512
BLOCK = 512
PRELOAD = 2  # paper §4.1.3: Preload count n=2 for the [C1][V1][C2] chain
MXU = 128


def issued_vs_useful(s_k: int, s_q: int):
    g = s_q * HEADS
    blocks = -(-s_k // BLOCK)
    k_pad = -(-DK // MXU) * MXU  # 576 -> 640
    useful = 2.0 * B * g * s_k * (DK + DV)
    issued = 2.0 * B * g * blocks * BLOCK * (k_pad + DV)
    return useful, issued, blocks


def measured_skip_rate(s_k: int, seed=0, rows=HEADS):
    """Fraction of KV blocks where the whole-program AMLA rescale increment
    is zero (all G=128 rows unchanged) — those blocks skip the (G x Dv)
    rescale entirely.  Streaming generation keeps memory flat for 500k."""
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (rows, DK)).astype(np.float32) / np.sqrt(DK)
    blocks = s_k // BLOCK
    m = np.full((rows,), numerics.M_INIT, np.float32)
    n = np.round(-m / numerics.LN2).astype(np.int64)
    gamma = np.ones((rows,), np.float32)
    skipped = 0
    for i in range(blocks):
        k_blk = rng.normal(0, 1, (BLOCK, DK)).astype(np.float32)
        blk = q @ k_blk.T
        m_new = np.maximum(m, blk.max(-1))
        n_new = np.round(-m_new / numerics.LN2).astype(np.int64)
        inv_r = np.exp(n_new * numerics.LN2 + m_new)
        s16 = (
            np.asarray(inv_r, np.float32).view(np.uint32) & 0xFFFF0000
        ).view(np.float32)
        gamma_new = inv_r / s16
        eps = gamma / gamma_new - 1.0
        inc = np.round(
            (np.maximum(n_new - n, -30) + 1.5 * eps) * (1 << 23)
        ).astype(np.int64)
        if i > 0 and np.all(inc == 0):
            skipped += 1
        m, n, gamma = m_new, n_new, gamma_new
    return skipped / max(blocks - 1, 1)


def run(csv_out=print):
    csv_out(
        "s_q,s_k,model_gflops,roofline_us,fu_structural,fu_modeled,"
        "est_us,amla_skip_rate,base_rescale_blocks,amla_rescale_blocks"
    )
    rows = []
    for s_q in (1, 2):
        for s_k in (1024, 2048, 3072, 4096, 6144, 16384, 131072):
            useful, issued, blocks = issued_vs_useful(s_k, s_q)
            fu_struct = useful / issued
            steady = blocks * s_q
            fu_model = fu_struct * steady / (steady + PRELOAD)
            t_roof = useful / PEAK_FLOPS * 1e6  # us, one chip
            est = t_roof / fu_model
            skip = measured_skip_rate(s_k)
            csv_out(
                f"{s_q},{s_k},{useful / 1e9:.1f},{t_roof:.1f},"
                f"{fu_struct:.3f},{fu_model:.3f},{est:.1f},"
                f"{skip:.2f},{blocks},{int(round((1 - skip) * blocks))}"
            )
            rows.append((s_q, s_k, fu_model, est, skip))
    return rows


if __name__ == "__main__":
    run()
