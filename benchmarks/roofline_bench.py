"""Aggregate the dry-run JSON records into the §Roofline table.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and prints
the per-(arch x shape x mesh) three-term roofline with dominant bottleneck,
useful-compute ratio, and a what-would-move-it hint.
"""

from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = os.path.join("experiments", "dryrun")


def hint(rec) -> str:
    r = rec["roofline"]
    dom = r["dominant"]
    if dom == "collective":
        kinds = rec.get("collectives", {}).get("bytes", {})
        if kinds:
            worst = max(kinds, key=kinds.get)
            return f"cut {worst} traffic (sharding/accum schedule)"
        return "cut collective traffic"
    if dom == "memory":
        return "fuse elementwise chains / widen per-chip tile"
    return "increase per-chip arithmetic intensity (larger microbatch)"


def run(csv_out=print, dryrun_dir: str = DEFAULT_DIR):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not files:
        csv_out(f"# no dry-run records in {dryrun_dir} "
                "(run: python -m repro.launch.dryrun)")
        return []
    csv_out(
        "arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
        "bound_ms,flops_per_dev,useful_ratio,roofline_fraction,hint"
    )
    rows = []
    for f in files:
        rec = json.load(open(f))
        r = rec["roofline"]
        csv_out(
            f"{rec['arch']},{rec['shape']},{rec['mesh']},"
            f"{r['compute_s']:.4f},{r['memory_s']:.4f},{r['collective_s']:.4f},"
            f"{r['dominant']},{r['bound_time_s'] * 1e3:.2f},"
            f"{r['flops_per_device']:.3e},{r['useful_ratio']:.3f},"
            f"{r['roofline_fraction']:.4f},{hint(rec)}"
        )
        rows.append(rec)
    return rows


if __name__ == "__main__":
    run()
