"""Paper Table 2 / Fig. 1: arithmetic intensity of attention variants.

AI = FLOPS / KV-bytes:  N1*S1 for MHA/GQA,  N1*S1*(Dk+Dv)/Dk for MLA
(paper §2.4), with the v5e roofline knee for context.
"""

from __future__ import annotations

from repro.roofline.analysis import HBM_BW, PEAK_FLOPS


def intensity(n1, n2, s1, dk, dv, mla):
    """AI = FLOPS / KV-bytes.  FLOPS = 2*N1*S1*S2*(Dk+Dv); bytes:
    2*N2*S2*(Dk+Dv) for MHA/GQA (per-head KV), 2*S2*Dk for MLA (shared
    latent) — giving N1*S1/N2 and N1*S1*(Dk+Dv)/Dk (paper Table 2)."""
    if mla:
        return n1 * s1 * (dk + dv) / dk
    return n1 * s1 / n2


VARIANTS = [
    # name, q_heads, kv_heads, s_q, mla?
    ("MHA", 64, 64, 1, False),
    ("GQA", 64, 8, 1, False),
    ("MLA-64", 64, 1, 1, True),
    ("MLA-128", 128, 1, 1, True),
    ("MLA-128(Sq=2)", 128, 1, 2, True),
]


def run(csv_out=print):
    knee = PEAK_FLOPS / HBM_BW
    csv_out("variant,q_heads,kv_heads,s_q,intensity_flops_per_byte,regime")
    rows = []
    for name, n1, n2, sq, mla in VARIANTS:
        ai = intensity(n1, n2, sq, 576, 512, mla)
        regime = "compute-bound" if ai > knee else "memory-bound"
        csv_out(f"{name},{n1},{n2},{sq},{ai:.1f},{regime}")
        rows.append((name, ai, regime))
    csv_out(f"# v5e roofline knee = {knee:.1f} FLOP/byte "
            f"(197 TFLOP/s over 819 GB/s)")
    return rows


if __name__ == "__main__":
    run()
